"""Chunked, versioned index snapshots (DESIGN.md §7).

One snapshot is a directory of uncompressed npz *pages* plus a JSON
manifest — the on-disk image of a backend's ``state_dict()``:

    snap_000000000042/
      manifest.json              format_version, kind, config, epoch,
                                 meta (keys/rng/…), array -> page table
      vectors.00000.npz          pages: rows [0, rows_per_page) of axis 0
      vectors.00001.npz          ...
      deleted.00000.npz

Pages are chunked along axis 0 at a byte budget (``page_bytes``) — the
analog of MeMemo writing IndexedDB rows in bounded batches (paper C3) —
so a multi-GB index never needs a single monolithic file and restore can
stream page by page. ``np.savez`` without compression stores the raw
array bytes, which keeps the secure-delete byte-absence test honest: a
compacted store must not contain a deleted vector's bytes anywhere, and
raw pages make that property directly checkable.

Atomicity follows ``train/checkpoint.py``: everything is written into
``<dir>.tmp`` (manifest last), then a single ``os.rename`` publishes the
snapshot. A crash mid-write leaves only a ``*.tmp`` directory, which
readers ignore and the store garbage-collects.
"""
from __future__ import annotations

import json
import os
import shutil

import numpy as np

FORMAT_VERSION = 1
MANIFEST_NAME = "manifest.json"


def _rows_per_page(shape: tuple, itemsize: int, page_bytes: int) -> int:
    row_bytes = max(int(np.prod(shape[1:], dtype=np.int64)) * itemsize, 1)
    return max(1, page_bytes // row_bytes)


def write_snapshot(dir_path: str, *, kind: str, config: dict, epoch: int,
                   arrays: dict, meta: dict,
                   page_bytes: int = 4 << 20) -> str:
    """Write one snapshot atomically; ``dir_path`` must not exist yet."""
    tmp = dir_path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest_arrays: dict = {}
    for name, arr in arrays.items():
        a = np.ascontiguousarray(arr)
        rows = _rows_per_page(a.shape, a.itemsize, page_bytes)
        n0 = a.shape[0]
        n_pages = max(-(-n0 // rows), 1)           # >= 1 page even when empty
        pages = []
        for p in range(n_pages):
            chunk = a[p * rows:(p + 1) * rows]
            fname = f"{name}.{p:05d}.npz"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.savez(f, data=chunk)            # uncompressed: raw bytes
            pages.append({"file": fname, "rows": int(chunk.shape[0])})
        manifest_arrays[name] = {"dtype": str(a.dtype),
                                 "shape": list(a.shape), "pages": pages}
    manifest = {"format_version": FORMAT_VERSION, "kind": kind,
                "config": config, "epoch": int(epoch), "meta": meta,
                "arrays": manifest_arrays}
    with open(os.path.join(tmp, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f)                     # manifest last: commit point
    os.rename(tmp, dir_path)                       # atomic publish
    return dir_path


def read_snapshot(dir_path: str) -> tuple[dict, dict]:
    """Load a snapshot -> (manifest, arrays). Pages are concatenated back
    along axis 0 and validated against the manifest's shape/dtype."""
    with open(os.path.join(dir_path, MANIFEST_NAME)) as f:
        manifest = json.load(f)
    if manifest["format_version"] > FORMAT_VERSION:
        raise ValueError(
            f"snapshot {dir_path} has format_version "
            f"{manifest['format_version']} > supported {FORMAT_VERSION}")
    arrays: dict = {}
    for name, spec in manifest["arrays"].items():
        parts = []
        for page in spec["pages"]:
            with np.load(os.path.join(dir_path, page["file"]),
                         allow_pickle=False) as z:
                parts.append(z["data"])
        a = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        if list(a.shape) != spec["shape"] or str(a.dtype) != spec["dtype"]:
            raise ValueError(
                f"snapshot {dir_path}: array {name!r} pages reassemble to "
                f"{a.shape}/{a.dtype}, manifest says "
                f"{spec['shape']}/{spec['dtype']}")
        arrays[name] = a
    return manifest, arrays
