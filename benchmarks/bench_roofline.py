"""Roofline table from the dry-run artifacts (launch/dryrun.py output).

Reads dryrun_pod_baseline.json / dryrun_tuned_both.json if present; cells
can be (re)generated with:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --preset tuned \
        --out dryrun_tuned_both.json
"""
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run(rows: list):
    for name in ("dryrun_pod_baseline.json", "dryrun_tuned_both.json"):
        path = os.path.join(ROOT, name)
        if not os.path.exists(path):
            rows.append((f"roofline_{name}", 0, "missing: run launch.dryrun"))
            continue
        cells = json.load(open(path))
        ok = [c for c in cells if c.get("status") == "ok"]
        tag = "baseline" if "baseline" in name else "tuned"
        for c in ok:
            step = max(c["t_compute_s"], c["t_memory_s"], c["t_collective_s"])
            rows.append((
                f"roofline_{tag}_{c['arch']}_{c['shape']}_{c['mesh']}",
                step * 1e6,
                f"bottleneck={c['bottleneck']},frac={c['roofline_fraction']:.2f},"
                f"useful={c['useful_ratio']:.2f},fits={c['fits_hbm']}"))
