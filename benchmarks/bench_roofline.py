"""Roofline table from the dry-run artifacts (launch/dryrun.py output),
plus one MEASURED row for the cross-shard top-k merge.

Reads dryrun_pod_baseline.json / dryrun_tuned_both.json if present; cells
can be (re)generated with:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --preset tuned \
        --out dryrun_tuned_both.json

The merge row times the compiled ppermute tree reduction
(collectives.topk_merge_axis) at S=8 on fake CPU devices and derives
the wire traffic per round — ceil(log2 S) rounds of B*k*(4+4) bytes per
shard (f32 dist + i32 id; bf16 wire halves the dist half) — against the
achieved effective bandwidth, with the host-python merge the tree
replaces alongside for contrast. The point the row makes: the merge is
BANDWIDTH-bound (bytes on the interconnect), not HOST-bound (Python
concat + argsort per batch), and per-hop traffic is k-sized, not
S*k-sized.
"""
import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")

_MERGE_CHILD = """
    import json, time
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.sharded import SHARD_AXIS, shard_mesh
    from repro.distributed.collectives import hierarchical_topk

    s, b, k, reps = 8, 64, 16, 20
    mesh = shard_mesh(s)
    fn = jax.jit(shard_map(
        lambda d, i: hierarchical_topk(d[0], i[0], k, (SHARD_AXIS,),
                                       tie_break_ids=True, axis_sizes=(s,)),
        mesh=mesh,
        in_specs=(P(SHARD_AXIS, None, None), P(SHARD_AXIS, None, None)),
        out_specs=(P(None, None), P(None, None)), check_rep=False))
    rng = np.random.default_rng(0)
    d = np.sort(rng.random((s, b, k)).astype(np.float32), -1)
    i = rng.permutation(s * b * k).astype(np.int32).reshape(s, b, k)
    spec = NamedSharding(mesh, P(SHARD_AXIS, None, None))
    dj, ij = jax.device_put(jnp.asarray(d), spec), jax.device_put(
        jnp.asarray(i), spec)
    jax.block_until_ready(fn(dj, ij))            # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(dj, ij))
    t_tree = (time.perf_counter() - t0) / reps

    def host_merge():                            # what the tree replaced
        dd = d.transpose(1, 0, 2).reshape(b, s * k)
        ii = i.transpose(1, 0, 2).reshape(b, s * k)
        j = np.argsort(dd, axis=1, kind="stable")[:, :k]
        return np.take_along_axis(dd, j, 1), np.take_along_axis(ii, j, 1)

    host_merge()
    t0 = time.perf_counter()
    for _ in range(reps):
        host_merge()
    t_host = (time.perf_counter() - t0) / reps

    rounds = (s - 1).bit_length()
    wire_round = b * k * (4 + 4)                 # f32 dist + i32 id, per shard
    total_bytes = s * rounds * wire_round
    allgather = b * (s - 1) * k * (4 + 4)        # the traffic the tree avoids
    print("ROW" + json.dumps({"s": s, "b": b, "k": k,
                              "t_tree_us": t_tree * 1e6,
                              "t_host_us": t_host * 1e6,
                              "rounds": rounds,
                              "wire_kb_round": wire_round / 1024,
                              "allgather_kb": allgather / 1024,
                              "gbps": total_bytes / t_tree / 1e9}))
"""


def run(rows: list):
    _merge_row(rows)
    for name in ("dryrun_pod_baseline.json", "dryrun_tuned_both.json"):
        path = os.path.join(ROOT, name)
        if not os.path.exists(path):
            rows.append((f"roofline_{name}", 0, "missing: run launch.dryrun"))
            continue
        cells = json.load(open(path))
        ok = [c for c in cells if c.get("status") == "ok"]
        tag = "baseline" if "baseline" in name else "tuned"
        for c in ok:
            step = max(c["t_compute_s"], c["t_memory_s"], c["t_collective_s"])
            rows.append((
                f"roofline_{tag}_{c['arch']}_{c['shape']}_{c['mesh']}",
                step * 1e6,
                f"bottleneck={c['bottleneck']},frac={c['roofline_fraction']:.2f},"
                f"useful={c['useful_ratio']:.2f},fits={c['fits_hbm']}"))


def _merge_row(rows: list):
    """Measured cross-shard merge roofline row (see module docstring)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_MERGE_CHILD)],
        capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != 0:
        rows.append(("roofline_merge_S8", 0,
                     f"FAILED:{proc.stderr[-200:]}"))
        return
    payload = next(line for line in proc.stdout.splitlines()
                   if line.startswith("ROW"))
    r = json.loads(payload[len("ROW"):])
    # on real interconnect the merge is bandwidth-bound (the point of the
    # k-sized per-hop traffic); on fake CPU devices the collective launch
    # fee dominates and we say so instead of faking the label
    bound = ("bandwidth" if r["t_tree_us"] <= r["t_host_us"]
             else "dispatch(cpu-sim)")
    rows.append((
        f"roofline_merge_S{r['s']}", r["t_tree_us"],
        f"rounds={r['rounds']},wire_kb_round={r['wire_kb_round']:.0f},"
        f"allgather_kb={r['allgather_kb']:.0f},"
        f"achieved_gbps={r['gbps']:.2f},host_merge_us={r['t_host_us']:.0f},"
        f"bound={bound}"))
