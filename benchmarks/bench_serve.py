"""Continuous-batching serving throughput (smoke LM, CPU)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine


def run(rows: list):
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    for slots in (1, 4):
        eng = ServeEngine(params, cfg, slots=slots, max_len=96,
                          dtype=jnp.float32)
        prompts = [np.arange(6 + i) % cfg.vocab for i in range(8)]
        eng.generate(prompts[:1], max_new_tokens=2)        # warm compile
        t0 = time.perf_counter()
        eng2 = ServeEngine(params, cfg, slots=slots, max_len=96,
                           dtype=jnp.float32)
        eng2.generate(prompts, max_new_tokens=12)
        dt = time.perf_counter() - t0
        tput = eng2.tokens_out / dt
        rows.append((f"serve_slots{slots}_8req", dt * 1e6,
                     f"tok_per_s={tput:.1f}"))
