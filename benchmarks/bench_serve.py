"""Continuous-batching serving throughput (smoke LM, CPU) + the batched
retrieval serving layer (RetrievalEngine, DESIGN.md §6).

Rows:
  serve_slots{S}_8req          LM continuous batching, tokens/s
  retrieval_seq_baseline       per-query index.query loop (the old path)
  retrieval_B{1,8,32,128}      RetrievalEngine bucket-coalesced QPS and
                               speedup over the per-query baseline (hnsw)
  retrieval_flat_B32           same harness over the exact flat backend
  retrieval_B32_cached         repeat workload served from the LRU cache
  retrieval_rag_e2e            generate_rag shim end-to-end: rides the
                               overlapped serving loop (bench_rag's
                               rag_e2e_slots* rows sweep it closed-loop)

Smoke mode (REPRO_BENCH_SMOKE=1, set by ``benchmarks/run.py --smoke``)
shrinks every size so the whole file runs in seconds — enough to catch
perf-path breakage (shape regressions, lost batching, cache misses) in CI
without a full run.
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import make_index
from repro.data.synthetic import make_corpus
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.serve.retrieval import RetrievalEngine

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _lm_serving(rows: list):
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    for slots in ((1,) if SMOKE else (1, 4)):
        eng = ServeEngine(params, cfg, slots=slots, max_len=96,
                          dtype=jnp.float32)
        prompts = [np.arange(6 + i) % cfg.vocab for i in range(8)]
        eng.generate(prompts[:1], max_new_tokens=2)        # warm compile
        t0 = time.perf_counter()
        eng2 = ServeEngine(params, cfg, slots=slots, max_len=96,
                           dtype=jnp.float32)
        eng2.generate(prompts, max_new_tokens=4 if SMOKE else 12)
        dt = time.perf_counter() - t0
        tput = eng2.tokens_out / dt
        rows.append((f"serve_slots{slots}_8req", dt * 1e6,
                     f"tok_per_s={tput:.1f}"))


def _retrieval_serving(rows: list):
    # hnsw at the recall>=0.97 operating point (ef=24, M=8): the per-query
    # baseline and every bucket pay the SAME ef search budget.
    n, dim, k, ef = (2_000, 32, 10, 24) if SMOKE else (10_000, 64, 10, 24)
    workload = 32 if SMOKE else 128
    data = make_corpus(n, dim, seed=0)
    rng = np.random.default_rng(1)
    queries = (data[rng.integers(0, n, workload)]
               + 0.15 * rng.normal(size=(workload, dim)).astype(np.float32))
    idx = make_index("hnsw", metric="cosine", M=8, ef_construction=60,
                     use_bulk_build=True)
    idx.bulk_insert([f"d{i}" for i in range(n)], data)

    # -- per-query baseline: what RAGPipeline.retrieve did before the engine
    idx.query(queries[0], k=k, ef=ef)                     # warm B=1 compile
    t0 = time.perf_counter()
    for q in queries:
        idx.query(q, k=k, ef=ef)
    dt_seq = time.perf_counter() - t0
    qps_seq = workload / dt_seq
    rows.append(("retrieval_seq_baseline", dt_seq / workload * 1e6,
                 f"qps={qps_seq:.0f} ef={ef}"))

    # -- bucket-coalesced engine at B in {1, 8, 32, 128} (cache off: pure
    #    device throughput; workload is submitted in chunks of B)
    for B in (1, 8, 32) if SMOKE else (1, 8, 32, 128):
        eng = RetrievalEngine(idx, max_batch=B, cache_size=0)
        eng.retrieve(queries[:B], k=k, ef=ef)             # warm this bucket
        t0 = time.perf_counter()
        for lo in range(0, workload, B):
            eng.retrieve(queries[lo:lo + B], k=k, ef=ef)
        dt = time.perf_counter() - t0
        qps = workload / dt
        rows.append((f"retrieval_B{B}", dt / workload * 1e6,
                     f"qps={qps:.0f} speedup_vs_seq={qps / qps_seq:.1f}x"))

    # -- the exact backend under the same harness (flat = one fused
    #    distance+topk dispatch per bucket; the fixed-cost amortisation is
    #    even larger than hnsw's)
    flat = make_index("flat", metric="cosine", dim=dim)
    flat.bulk_insert([f"d{i}" for i in range(n)], data)
    flat.query(queries[0], k=k)
    t0 = time.perf_counter()
    for q in queries:
        flat.query(q, k=k)
    dt_fseq = time.perf_counter() - t0
    eng = RetrievalEngine(flat, max_batch=32, cache_size=0)
    eng.retrieve(queries[:32], k=k)
    t0 = time.perf_counter()
    for lo in range(0, workload, 32):
        eng.retrieve(queries[lo:lo + 32], k=k)
    dt = time.perf_counter() - t0
    rows.append(("retrieval_flat_B32", dt / workload * 1e6,
                 f"qps={workload / dt:.0f} "
                 f"speedup_vs_seq={dt_fseq / dt:.1f}x"))

    # -- repeat workload with the LRU cache on: served without any device
    #    search (the cache-epoch design, DESIGN.md §6); hit_rate is the
    #    repeat pass's alone
    B = 32
    eng = RetrievalEngine(idx, max_batch=B, cache_size=4 * workload)
    for lo in range(0, workload, B):
        eng.retrieve(queries[lo:lo + B], k=k, ef=ef)      # populate
    searches_before = eng.stats.searches
    hits_before = eng.stats.cache_hits
    t0 = time.perf_counter()
    for lo in range(0, workload, B):
        eng.retrieve(queries[lo:lo + B], k=k, ef=ef)
    dt = time.perf_counter() - t0
    assert eng.stats.searches == searches_before, "cached repeat hit device"
    hit_rate = (eng.stats.cache_hits - hits_before) / workload
    rows.append(("retrieval_B32_cached", dt / workload * 1e6,
                 f"qps={workload / dt:.0f} hit_rate={hit_rate:.2f}"))


def _rag_e2e(rows: list):
    """generate_rag (compat shim) end-to-end: the whole batch is submitted
    up front, so retrieval coalesces into one early tick and generation
    is slot-batched — kept as the open-loop burst reference point next to
    bench_rag's closed-loop rag_e2e_slots* rows."""
    from repro.data.corpus import BUILTIN_CORPUS
    from repro.serve.rag import RAGPipeline

    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, slots=2, max_len=96, dtype=jnp.float32)
    rag = RAGPipeline(index_kind="hnsw")
    rag.add_documents(BUILTIN_CORPUS)
    reqs = 6 if SMOKE else 18
    queries = [["how does hnsw search work",
                "why is on device retrieval private",
                "what does efConstruction control"][i % 3]
               for i in range(reqs)]
    t0 = time.perf_counter()
    eng.generate_rag(rag, queries, k=3, max_new_tokens=2 if SMOKE else 8)
    dt = time.perf_counter() - t0
    s = rag.retriever.stats.as_dict()
    rows.append(("retrieval_rag_e2e", dt / reqs * 1e6,
                 f"req_per_s={reqs / dt:.1f} searches={s['searches']} "
                 f"hit_rate={s['hit_rate']:.2f}"))


def run(rows: list):
    _lm_serving(rows)
    _retrieval_serving(rows)
    _rag_e2e(rows)
