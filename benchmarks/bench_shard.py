"""Mesh-sharded retrieval sweep: shard count x corpus size (DESIGN.md §8).

Rows:
  shard_S{S}_n{N}    per-query critical-path latency at S shards: one
                     shard's local exact scan over ceil(N/S) rows plus
                     the S-way hierarchical top-k merge — the latency a
                     real S-device mesh pays, since shards genuinely run
                     concurrently there. derived: speedup vs S=1, this
                     host's wall-clock for the REAL sharded dispatch
                     (``host_wall_us``), rows per device, and the
                     aggregate-capacity headroom (S x one device's HBM).

Methodology note: CI hosts have ~2 cores, so the wall-clock of 8
simulated shards oversubscribes and says nothing about mesh scaling —
the critical-path decomposition (local scan at N/S + k*S merge) is the
projection that does, and ``host_wall_us`` keeps the raw measurement
honest alongside it. On a pod-slice the two converge.

The sharded path needs a multi-device mesh, so this suite spawns ONE
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
set before jax imports (the same idiom as tests/test_distributed.py) and
sweeps shard counts inside it — each S builds its mesh over the first S
fake devices. Smoke mode shrinks N for CI; the full run measures the
acceptance shape (N=100k, S in 1..8).
"""
import json
import os
import subprocess
import sys
import textwrap

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CHILD = """
    import json, time
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import make_index
    from repro.data.synthetic import make_corpus

    ns = {ns}
    shard_counts = {shard_counts}
    dim, b, k, reps = {dim}, {b}, {k}, {reps}

    def timed(fn, *args):
        fn(*args)                                   # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / reps

    out = []
    for n in ns:
        data = make_corpus(n, dim, seed=0)
        keys = [f"d{{i}}" for i in range(n)]
        rng = np.random.default_rng(1)
        q = (data[rng.integers(0, n, b)]
             + 0.1 * rng.normal(size=(b, dim)).astype(np.float32))
        base_us = None
        for s in shard_counts:
            # real sharded dispatch on this host (fan-out + merge)
            idx = make_index("flat", dim=dim, metric="cosine", n_shards=s)
            idx.bulk_insert(keys, data)
            wall = timed(lambda: idx.query_batch(q, k=k)[1])

            # critical path: ONE shard's local scan over ceil(n/s) rows...
            rows_per = -(-n // s)
            local = make_index("flat", dim=dim, metric="cosine")
            local.bulk_insert(keys[:rows_per], data[:rows_per])
            t_local = timed(lambda: local.query_batch(q, k=k)[1])
            # ...plus the s-way k-candidate merge
            cd = jnp.asarray(rng.normal(size=(b, s * k)).astype(np.float32))
            t_merge = timed(
                jax.jit(lambda d: jax.lax.top_k(-d, k)), cd) if s > 1 else 0.0

            crit_us = (t_local + t_merge) / b * 1e6
            if base_us is None:
                base_us = crit_us
            out.append({{"s": s, "n": n, "us": crit_us,
                         "wall_us": wall / b * 1e6,
                         "speedup": base_us / crit_us,
                         "rows_per_dev": rows_per}})
    print("ROWS" + json.dumps(out))
"""


def run(rows: list):
    if SMOKE:
        ns, shard_counts, dim, b, k, reps = [20_000], [1, 2, 4, 8], 32, 8, 10, 2
    else:
        ns, shard_counts, dim, b, k, reps = [100_000], [1, 2, 4, 8], 64, 8, 10, 3
    code = textwrap.dedent(_CHILD.format(
        ns=ns, shard_counts=shard_counts, dim=dim, b=b, k=k, reps=reps))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_shard child failed: {proc.stderr[-2000:]}")
    payload = next(line for line in proc.stdout.splitlines()
                   if line.startswith("ROWS"))
    for r in json.loads(payload[len("ROWS"):]):
        rows.append((f"shard_S{r['s']}_n{r['n']}", r["us"],
                     f"speedup={r['speedup']:.2f}x,"
                     f"host_wall_us={r['wall_us']:.0f},"
                     f"rows_per_dev={r['rows_per_dev']},"
                     f"capacity_headroom={r['s']}x"))
