"""Mesh-sharded retrieval sweep: shard count x corpus size (DESIGN.md §8).

Rows:
  shard_S{S}_n{N}       per-query critical-path latency at S shards: one
                        shard's local exact scan over ceil(N/S) rows plus
                        the tree merge's per-shard critical path —
                        ceil(log2 S) sequential rounds, each a measured
                        compiled two-key pairwise merge (the exact
                        ``_merge_pair`` program every ppermute round
                        runs). derived: speedup vs S=1, ``merge_us``
                        (wall of the REAL full S-way compiled
                        ``hierarchical_topk`` program — reported raw, not
                        folded into speedup, because on an oversubscribed
                        CPU simulator it is dominated by scheduling S
                        device threads on ~2 cores), the host's
                        wall-clock for the real sharded dispatch
                        (``host_wall_us``), rows per device, and the
                        aggregate-capacity headroom.
  shard_hnsw_S{S}_n{N}  sharded HNSW segment-set sweep: wall per-query
                        latency of the one-dispatch stacked fan-out
                        (core/stacked.py) vs the per-child Python loop
                        (``loop_us``) — the dispatch-count win the
                        compiled path buys, visible in BENCH_smoke.json.

Methodology note: CI hosts have ~2 cores, so the wall-clock of 8
simulated shards oversubscribes and says nothing about mesh scaling —
the critical-path decomposition (local scan at N/S + rounds x pairwise
merge) is the projection that does, and ``host_wall_us`` / ``merge_us``
keep the raw measurements honest alongside it. On a pod-slice the
projections and the walls converge. Both merge numbers are measured
compiled programs, not proxies: ``merge_us`` is the full S-way
shard_map tree (ppermute rounds included) and the per-round term is the
identical pairwise keep-k kernel on one device.

The sharded path needs a multi-device mesh, so this suite spawns ONE
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
set before jax imports (the same idiom as tests/test_distributed.py) and
sweeps shard counts inside it — each S builds its mesh over the first S
fake devices. Smoke mode shrinks N for CI; the full run measures the
acceptance shape (N=100k, S in 1..8).
"""
import json
import os
import subprocess
import sys
import textwrap

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CHILD = """
    import json, time
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import make_index
    from repro.core.sharded import SHARD_AXIS, shard_mesh
    from repro.distributed.collectives import _merge_pair, hierarchical_topk
    from repro.data.synthetic import make_corpus

    ns = {ns}
    shard_counts = {shard_counts}
    dim, b, k, reps = {dim}, {b}, {k}, {reps}
    hnsw_n = {hnsw_n}

    def timed(fn, *args):
        fn(*args)                                   # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / reps

    def timed_host(fn, *args):
        fn(*args)                                   # warm any lazy state
        t0 = time.perf_counter()
        for _ in range(reps):
            fn(*args)
        return (time.perf_counter() - t0) / reps

    def merge_fn(s):
        # the REAL cross-shard merge: the same compiled ppermute tree
        # reduction the fan-out paths run (collectives.topk_merge_axis)
        mesh = shard_mesh(s)
        f = shard_map(
            lambda d, i: hierarchical_topk(d[0], i[0], k, (SHARD_AXIS,),
                                           tie_break_ids=True,
                                           axis_sizes=(s,)),
            mesh=mesh,
            in_specs=(P(SHARD_AXIS, None, None), P(SHARD_AXIS, None, None)),
            out_specs=(P(None, None), P(None, None)), check_rep=False)
        return jax.jit(f), mesh

    out = []
    for n in ns:
        data = make_corpus(n, dim, seed=0)
        keys = [f"d{{i}}" for i in range(n)]
        rng = np.random.default_rng(1)
        q = (data[rng.integers(0, n, b)]
             + 0.1 * rng.normal(size=(b, dim)).astype(np.float32))
        base_us = None
        for s in shard_counts:
            # real sharded dispatch on this host (fan-out + merge)
            idx = make_index("flat", dim=dim, metric="cosine", n_shards=s)
            idx.bulk_insert(keys, data)
            wall = timed(lambda: idx.query_batch(q, k=k)[1])

            # critical path: ONE shard's local scan over ceil(n/s) rows...
            rows_per = -(-n // s)
            local = make_index("flat", dim=dim, metric="cosine")
            local.bulk_insert(keys[:rows_per], data[:rows_per])
            t_local = timed(lambda: local.query_batch(q, k=k)[1])
            # ...plus the merge's per-shard critical path: ceil(log2 s)
            # sequential rounds of the two-key pairwise keep-k — the
            # exact per-round program, timed compiled on ONE device so
            # core oversubscription in the simulator can't pollute it
            if s > 1:
                pair = jax.jit(lambda d1, i1, d2, i2:
                               _merge_pair(d1, i1, d2, i2, k, True))
                cd = np.sort(rng.random((2, b, k)).astype(np.float32), -1)
                ci = rng.permutation(2 * b * k).astype(np.int32)
                ci = ci.reshape(2, b, k)
                t_pair = timed(pair, cd[0], ci[0], cd[1], ci[1])
                rounds = (s - 1).bit_length()
                t_merge = rounds * t_pair
                # the REAL full s-way compiled tree, for the record
                mfn, mesh = merge_fn(s)
                md = np.sort(rng.random((s, b, k)).astype(np.float32), -1)
                mi = rng.permutation(s * b * k).astype(np.int32)
                mi = mi.reshape(s, b, k)
                spec = NamedSharding(mesh, P(SHARD_AXIS, None, None))
                t_full = timed(mfn, jax.device_put(jnp.asarray(md), spec),
                               jax.device_put(jnp.asarray(mi), spec))
            else:
                t_merge = t_full = 0.0

            crit_us = (t_local + t_merge) / b * 1e6
            if base_us is None:
                base_us = crit_us
            out.append({{"row": "flat", "s": s, "n": n, "us": crit_us,
                         "merge_us": t_full / b * 1e6,
                         "wall_us": wall / b * 1e6,
                         "speedup": base_us / crit_us,
                         "rows_per_dev": rows_per}})

        # sharded HNSW segment-set sweep: one-dispatch stacked fan-out
        # vs the per-child Python loop (the pre-compiled-path cost)
        hd = data[:hnsw_n]
        hq = q
        for s in shard_counts:
            idx = make_index("hnsw", metric="cosine", M=8,
                             ef_construction=40, ef_search=32, n_shards=s,
                             use_bulk_build=True)
            idx.bulk_insert(keys[:hnsw_n], hd)
            wall = timed_host(lambda: idx.query_batch(hq, k=k)[1])
            loop = (timed_host(
                        lambda: idx._query_batch_sharded_loop(hq, k, None)[1])
                    if s > 1 else wall)
            out.append({{"row": "hnsw", "s": s, "n": hnsw_n,
                         "us": wall / b * 1e6, "loop_us": loop / b * 1e6,
                         "speedup_vs_loop": loop / wall}})
    print("ROWS" + json.dumps(out))
"""


def run(rows: list):
    # batch 128: the fake-device collective program carries a ~ms fixed
    # launch fee (8 device threads on a 2-core CI host) that is pure
    # simulation artifact; a serving-sized batch amortizes it so
    # merge_us reflects per-query cost, not 1/b of a scheduling fee
    if SMOKE:
        ns, shard_counts, dim, b, k, reps = [20_000], [1, 2, 4, 8], 32, 128, 10, 3
        hnsw_n = 2_000
    else:
        ns, shard_counts, dim, b, k, reps = [100_000], [1, 2, 4, 8], 64, 128, 10, 3
        hnsw_n = 20_000
    code = textwrap.dedent(_CHILD.format(
        ns=ns, shard_counts=shard_counts, dim=dim, b=b, k=k, reps=reps,
        hnsw_n=hnsw_n))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_shard child failed: {proc.stderr[-2000:]}")
    payload = next(line for line in proc.stdout.splitlines()
                   if line.startswith("ROWS"))
    for r in json.loads(payload[len("ROWS"):]):
        if r["row"] == "flat":
            rows.append((f"shard_S{r['s']}_n{r['n']}", r["us"],
                         f"speedup={r['speedup']:.2f}x,"
                         f"merge_us={r['merge_us']:.0f},"
                         f"host_wall_us={r['wall_us']:.0f},"
                         f"rows_per_dev={r['rows_per_dev']},"
                         f"capacity_headroom={r['s']}x"))
        else:
            rows.append((f"shard_hnsw_S{r['s']}_n{r['n']}", r["us"],
                         f"loop_us={r['loop_us']:.0f},"
                         f"speedup_vs_loop={r['speedup_vs_loop']:.2f}x,"
                         f"dispatches=1"))
