"""Multi-tenant IndexPool benchmarks (DESIGN.md §10): what does pooling
cost, and what does it buy?

MeMemo's deployment shape is millions of *small* private corpora, so the
interesting axes are per-tenant overheads, not raw corpus throughput:

  * ``tenant_query_n<N>`` — a resident tenant's query latency through
    the pool's slab path vs a dedicated single flat index over the same
    rows. ``vs_single`` is the ratio (acceptance: <= 1.5x — the slab
    gather + shared-arena top-k must stay within shouting distance of
    the dedicated kernel);
  * ``tenant_page_n<N>`` — evict wall time (snapshot + arena removal +
    derived-cache drop) and restore wall time (bit-for-bit warm restore
    adopted back into the arena), per cycle;
  * ``tenant_multi_b<B>`` — the cross-tenant serving tick: one
    ``query_batch_multi`` dispatch whose rows round-robin over the
    resident tenants, vs issuing one dispatch per tenant;
  * ``tenant_density`` — tenants/GB at the benchmark's tenant size from
    ``arena_device_bytes()`` (slab padding included — this is the real
    packing density, not the ideal one).

Smoke mode (REPRO_BENCH_SMOKE=1) shrinks everything to a seconds-scale
canary; CI asserts the tenant rows exist in BENCH_smoke.json and that
``vs_single`` holds the 1.5x acceptance bound.
"""
import os
import shutil
import tempfile
import time

import numpy as np

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _time(fn, iters):
    fn()                                     # warm (pack + compile)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run(rows: list):
    from repro.core import IndexPool, make_index

    n_tenants = 8 if SMOKE else 32
    per_tenant = 128 if SMOKE else 1024
    dim = 64 if SMOKE else 128
    b, k = 16, 10
    iters = 5 if SMOKE else 20

    rng = np.random.default_rng(0)
    data = rng.normal(size=(n_tenants, per_tenant, dim)).astype(np.float32)
    queries = rng.normal(size=(b, dim)).astype(np.float32)
    keys = [f"d{i}" for i in range(per_tenant)]

    root = tempfile.mkdtemp(prefix="bench_tenant_")
    try:
        pool = IndexPool(root, dim=dim, slab_rows=per_tenant)
        for t in range(n_tenants):
            pool.bulk_insert(f"t{t}", keys, data[t])

        single = make_index("flat", dim=dim, metric="cosine")
        single.bulk_insert(keys, data[0])

        # --- resident-tenant query latency vs the dedicated index
        dt_pool = _time(lambda: pool.query_batch("t0", queries, k=k),
                        iters)
        dt_single = _time(lambda: single.query_batch(queries, k=k), iters)
        ratio = dt_pool / max(dt_single, 1e-9)
        rows.append((f"tenant_query_n{per_tenant}", dt_pool * 1e6 / b,
                     f"single_us={dt_single * 1e6 / b:.1f} "
                     f"vs_single={ratio:.2f}x tenants={n_tenants}"))

        # --- cross-tenant tick: ONE dispatch for a mixed batch
        tenants = [f"t{i % n_tenants}" for i in range(b)]
        dt_multi = _time(
            lambda: pool.query_batch_multi(queries, tenants, k=k), iters)
        loop_tenants = sorted(set(tenants))
        dt_loop = _time(
            lambda: [pool.query_batch(t, queries[:1], k=k)
                     for t in loop_tenants], iters)
        rows.append((f"tenant_multi_b{b}", dt_multi * 1e6 / b,
                     f"per_tenant_loop_us={dt_loop * 1e6:.1f} "
                     f"uniq_tenants={len(loop_tenants)}"))

        # --- paging: evict + restore wall time per cycle
        cycles = 2 if SMOKE else 5
        pool.evict("t1")
        pool.admit("t1")                     # warm (snapshot dirs exist)
        ev = rs = 0.0
        for _ in range(cycles):
            t0 = time.perf_counter()
            pool.evict("t1")
            ev += time.perf_counter() - t0
            t0 = time.perf_counter()
            pool.admit("t1")
            rs += time.perf_counter() - t0
        rows.append((f"tenant_page_n{per_tenant}",
                     (ev + rs) * 1e6 / cycles,
                     f"evict_ms={ev * 1e3 / cycles:.1f} "
                     f"restore_ms={rs * 1e3 / cycles:.1f} "
                     f"rows={per_tenant}"))

        # --- packing density: tenants per GB of device arena
        arena_bytes = pool._arena.arena_device_bytes()
        per_gb = (1 << 30) / max(arena_bytes / n_tenants, 1)
        rows.append(("tenant_density", 0.0,
                     f"arena_MB={arena_bytes / 2**20:.1f} "
                     f"tenants_per_GB={per_gb:.0f} "
                     f"rows_per_tenant={per_tenant} dim={dim}"))
    finally:
        shutil.rmtree(root, ignore_errors=True)
