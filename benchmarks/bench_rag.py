"""RAG Playground end-to-end (paper §2): encode -> retrieve -> prompt ->
generate, measuring per-stage latency with the smoke LM — plus the
overlapped serving loop (DESIGN.md §11) at slot counts {1, 4, 8}.

Rows:
  rag_index_12_docs       embed + index + store the builtin corpus
  rag_retrieve_top3       one warm retrieval through the RetrievalEngine
  rag_answer_e2e          single-request answer(): retrieve -> prompt ->
                          generate (the paper's sequential loop)
  rag_e2e_slots{1,4,8}    closed-loop submit_rag serving: per-request
                          latency, with req_per_s / overlap_ratio /
                          occupancy in the detail column. Requests arrive
                          closed-loop (2*slots outstanding), so late
                          arrivals' ANN searches run behind in-flight
                          decode dispatches — req/s should grow with
                          slots, and overlap_ratio > 0 shows retrieval
                          actually hiding behind decode.

Smoke mode (REPRO_BENCH_SMOKE=1, set by ``benchmarks/run.py --smoke``)
shrinks request counts and generation budgets so CI can assert the
slot-scaling shape in seconds.
"""
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.corpus import BUILTIN_CORPUS
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.serve.rag import RAGPipeline, lm_generate_fn

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _pipeline_stages(rows: list):
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, slots=2, max_len=128,
                         dtype=jnp.float32)
    rag = RAGPipeline(generate_fn=lm_generate_fn(engine, cfg.vocab, 96))
    t0 = time.perf_counter()
    rag.add_documents(BUILTIN_CORPUS)
    rows.append(("rag_index_12_docs", (time.perf_counter() - t0) * 1e6, ""))

    q = "how does mememo prefetch vectors from slow storage?"
    rag.retrieve(q, k=3)                                   # warm
    t0 = time.perf_counter()
    docs = rag.retrieve(q, k=3)
    rows.append(("rag_retrieve_top3", (time.perf_counter() - t0) * 1e6,
                 f"top1={docs[0].key}"))

    t0 = time.perf_counter()
    out = rag.answer(q, k=3)
    rows.append(("rag_answer_e2e", (time.perf_counter() - t0) * 1e6,
                 f"resp_tokens={len(out['response'].split())}"))


def _drive_closed_loop(eng, queries, *, window, max_new):
    """Submit closed-loop (keep ``window`` requests outstanding) and tick
    until drained; returns (requests, wall seconds)."""
    pend = list(queries)
    live = []
    t0 = time.perf_counter()
    while pend or eng._work_pending():
        while pend and sum(not r.done for r in live) < window:
            live.append(eng.submit_rag(pend.pop(0), k=3,
                                       max_new_tokens=max_new))
        eng.step()
    dt = time.perf_counter() - t0
    eng.poll()
    return live, dt


def _overlapped_e2e(rows: list):
    """Closed-loop serving throughput vs slot count: the tentpole row.
    Unique queries per request keep the LRU cache out of the picture —
    every request pays a real ANN search, and the engine has to hide it
    behind decode ticks to scale. A full untimed pass (distinct query
    strings, same shape structure) warms each engine's prefill/decode
    compiles first, so the timed pass measures serving, not XLA."""
    from repro.serve.engine import EngineStats

    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    reqs = 20 if SMOKE else 32
    max_new = 3 if SMOKE else 8
    topics = ["hnsw graph search", "on device privacy", "document store",
              "vector distance", "flat scan cost", "delete retraction"]
    for slots in (1, 4, 8):
        rag = RAGPipeline(index_kind="hnsw")
        rag.add_documents(BUILTIN_CORPUS)
        eng = ServeEngine(params, cfg, pipeline=rag, slots=slots,
                          max_len=96, dtype=jnp.float32)
        window = 2 * slots
        _drive_closed_loop(
            eng, [f"{topics[i % len(topics)]} warm {i}" for i in range(reqs)],
            window=window, max_new=max_new)
        eng.stats = EngineStats(slots=slots)        # timed pass only
        live, dt = _drive_closed_loop(
            eng, [f"{topics[i % len(topics)]} variant {i}"
                  for i in range(reqs)],
            window=window, max_new=max_new)
        assert all(r.done and r.docs for r in live)
        s = eng.stats.as_dict()
        rows.append((f"rag_e2e_slots{slots}", dt / reqs * 1e6,
                     f"req_per_s={reqs / dt:.2f} "
                     f"overlap_ratio={s['overlap_ratio']:.2f} "
                     f"occupancy={s['slot_occupancy']:.2f}"))


def run(rows: list):
    _pipeline_stages(rows)
    _overlapped_e2e(rows)
