"""RAG Playground end-to-end (paper §2): encode -> retrieve -> prompt ->
generate, measuring per-stage latency with the smoke LM."""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.corpus import BUILTIN_CORPUS
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.serve.rag import RAGPipeline, lm_generate_fn


def run(rows: list):
    cfg = get_smoke_config("llama3-8b")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, slots=2, max_len=128,
                         dtype=jnp.float32)
    rag = RAGPipeline(generate_fn=lm_generate_fn(engine, cfg.vocab, 96))
    t0 = time.perf_counter()
    rag.add_documents(BUILTIN_CORPUS)
    rows.append(("rag_index_12_docs", (time.perf_counter() - t0) * 1e6, ""))

    q = "how does mememo prefetch vectors from slow storage?"
    rag.retrieve(q, k=3)                                   # warm
    t0 = time.perf_counter()
    docs = rag.retrieve(q, k=3)
    rows.append(("rag_retrieve_top3", (time.perf_counter() - t0) * 1e6,
                 f"top1={docs[0].key}"))

    t0 = time.perf_counter()
    out = rag.answer(q, k=3)
    rows.append(("rag_answer_e2e", (time.perf_counter() - t0) * 1e6,
                 f"resp_tokens={len(out['response'].split())}"))
