"""Paper §3.2: graph-aware prefetching amortises slow-tier transactions.
Sweeps the prefetch parameter p and reports transactions + hit rate; the
paper's auto-p (from the vector dim) is marked."""
from repro.core import hnsw_build
from repro.core.tiered import auto_prefetch_p, simulate_search_traffic
from repro.data.synthetic import make_corpus


def run(rows: list):
    n, dim = 4000, 96
    data = make_corpus(n, dim, seed=0)
    queries = make_corpus(30, dim, seed=1)
    g = hnsw_build.build_sequential(data, M=8, ef_construction=40)
    base = simulate_search_traffic(g, queries, ef=32, cache_rows=512,
                                   prefetch_p=1, use_graph_prefetch=False)
    rows.append(("tiered_no_prefetch", base.transactions,
                 f"hit_rate={base.as_dict()['hit_rate']:.3f}"))
    auto_p = auto_prefetch_p(dim)
    for p in (4, 16, 64, min(auto_p, 256)):
        s = simulate_search_traffic(g, queries, ef=32, cache_rows=512,
                                    prefetch_p=p)
        tag = "auto" if p == min(auto_p, 256) else str(p)
        rows.append((f"tiered_prefetch_p{tag}", s.transactions,
                     f"hit_rate={s.as_dict()['hit_rate']:.3f},"
                     f"saved={base.transactions / max(s.transactions, 1):.2f}x"))
