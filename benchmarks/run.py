"""Benchmark harness — one module per paper table/figure + the roofline
table from the dry-run. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only build,query,...]
"""
import argparse
import sys
import time

SUITES = ["build", "query", "tiered", "rag", "serve", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    rows: list = []
    print("name,us_per_call,derived")
    for suite in SUITES:
        if suite not in only:
            continue
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["run"])
        t0 = time.perf_counter()
        n_before = len(rows)
        try:
            mod.run(rows)
        except Exception as e:  # keep the harness going; report the failure
            rows.append((f"{suite}_FAILED", 0, f"{type(e).__name__}:{e}"))
        for name, us, derived in rows[n_before:]:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        print(f"# suite {suite} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
