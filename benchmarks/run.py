"""Benchmark harness — one module per paper table/figure + the roofline
table from the dry-run. Prints ``name,us_per_call,derived`` CSV; with
``--json PATH`` also writes the machine-readable trajectory file
(schema in benchmarks/README.md).

    PYTHONPATH=src python -m benchmarks.run [--only build,query,...]
        [--smoke] [--json BENCH_out.json]

``--smoke`` sets REPRO_BENCH_SMOKE=1: every suite that honors it shrinks
to a seconds-scale configuration — the perf-path canary CI runs via
``scripts/run_tests.sh --smoke``.
"""
import argparse
import json
import os
import sys
import time

SUITES = ["build", "query", "tiered", "rag", "serve", "store", "shard",
          "memory", "tenant", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configurations (sets REPRO_BENCH_SMOKE=1)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (see benchmarks/README.md)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    rows: list = []
    failures = 0
    print("name,us_per_call,derived")
    for suite in SUITES:
        if suite not in only:
            continue
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["run"])
        t0 = time.perf_counter()
        n_before = len(rows)
        try:
            mod.run(rows)
        except Exception as e:  # keep the harness going; report the failure
            failures += 1
            rows.append((f"{suite}_FAILED", 0, f"{type(e).__name__}:{e}"))
        for name, us, derived in rows[n_before:]:
            print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        print(f"# suite {suite} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)

    if args.json:
        out_dir = os.path.dirname(args.json)
        if out_dir:                      # CI writes to bench-results/…
            os.makedirs(out_dir, exist_ok=True)
        payload = {
            "schema_version": 1,
            "smoke": bool(args.smoke),
            "suites": sorted(only & set(SUITES)),
            "rows": [{"name": name, "us_per_call": round(float(us), 1),
                      "derived": derived} for name, us, derived in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json} ({len(rows)} rows)", file=sys.stderr)

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
