"""Storage-codec benchmarks (DESIGN.md §9): what do bf16/int8 rows buy,
and what do they cost?

MeMemo's browser setting makes BYTES the binding constraint — a 1M x
768-d fp32 corpus is ~3 GB of device blocks and ~3 GB of snapshot before
FLOPs ever matter. Rows here quantify the codec layer on the flat
backend (exact search, so recall isolates pure quantization error):

  * ``memory_<dtype>_n<N>`` — query latency (us/query at B=32) with
    derived columns:
      - ``dev_B_per_vec``  device bytes per vector (packed blocks +
                           scale table), ``dev_save`` vs fp32;
      - ``snap_B_per_vec`` snapshot bytes per vector on disk (encoded
                           pages + scales + manifest), ``snap_save``;
      - ``recall10``       recall@10 vs the fp32 index over the same
                           corpus (fp32 row = 1.0 by construction).

Smoke mode (REPRO_BENCH_SMOKE=1) shrinks N to a seconds-scale canary —
CI asserts these rows exist in BENCH_smoke.json, so a codec that stops
encoding (or a snapshot that silently falls back to fp32 pages) fails
the smoke job on byte counts, not just on tests.
"""
import os
import shutil
import tempfile
import time

import numpy as np

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DTYPES = ("fp32", "bf16", "int8")


def _dir_bytes(root: str) -> int:
    total = 0
    for dp, _, fns in os.walk(root):
        for fn in fns:
            total += os.path.getsize(os.path.join(dp, fn))
    return total


def _recall(found, truth) -> float:
    hits = sum(len(set(a) & set(b)) for a, b in zip(found, truth))
    return hits / max(sum(len(b) for b in truth), 1)


def run(rows: list):
    from repro.core import make_index
    from repro.store import IndexStore

    sizes = [2_000] if SMOKE else [20_000, 100_000]
    dim = 64 if SMOKE else 128
    b, k, iters = 32, 10, (3 if SMOKE else 10)
    rng = np.random.default_rng(0)
    queries = rng.normal(size=(b, dim)).astype(np.float32)

    for n in sizes:
        data = rng.normal(size=(n, dim)).astype(np.float32)
        keys = [f"d{i}" for i in range(n)]
        baseline = {}
        truth = None
        for dtype in DTYPES:
            root = tempfile.mkdtemp(prefix=f"bench_memory_{dtype}_")
            try:
                idx = make_index("flat", dim=dim, metric="cosine",
                                 dtype=dtype,
                                 store=IndexStore(os.path.join(root, "s")))
                idx.bulk_insert(keys, data)
                idx.query_batch(queries, k)          # pack + compile
                t0 = time.perf_counter()
                for _ in range(iters):
                    found, _ = idx.query_batch(queries, k)
                dt = (time.perf_counter() - t0) / (iters * b)
                if truth is None:                    # fp32 runs first
                    truth = found
                recall = _recall(found, truth)

                dev = idx._rows.device_block_bytes() / n
                idx._store.snapshot(idx)
                snap = _dir_bytes(os.path.join(root, "s")) / n
                baseline.setdefault("dev", dev)
                baseline.setdefault("snap", snap)
                rows.append((
                    f"memory_{dtype}_n{n}", dt * 1e6,
                    f"dev_B_per_vec={dev:.1f} "
                    f"dev_save={baseline['dev'] / max(dev, 1e-9):.2f}x "
                    f"snap_B_per_vec={snap:.1f} "
                    f"snap_save={baseline['snap'] / max(snap, 1e-9):.2f}x "
                    f"recall10={recall:.3f}"))
            finally:
                shutil.rmtree(root, ignore_errors=True)
