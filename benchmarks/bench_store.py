"""Durable store benchmarks (DESIGN.md §7): what does persistence cost,
and what does warm restore buy?

The paper's §5 headline is that building 1M x 384-d HNSW in the browser
takes ~94 minutes — which is exactly why MeMemo persists the index in
IndexedDB instead of rebuilding per session. Rows here quantify our
analog:

  * ``store_snapshot_*``   — chunked snapshot write throughput (MB/s);
  * ``store_restore_*``    — warm restore (snapshot + attach, NO graph
                             rebuild) vs ``store_cold_build_*``, the
                             re-embed-and-rebuild path restore replaces —
                             the speedup is the reason the store exists;
  * ``store_wal_append_*`` — per-mutation WAL overhead on the insert path
                             (logged vs unlogged insert);
  * ``store_wal_replay_*`` — crash-recovery replay rate (ops/s through
                             the ``_*_impl`` layer);
  * ``store_compact_*``    — secure-delete compaction (page rewrite +
                             WAL truncation) after deleting 10% of rows.

Smoke mode (REPRO_BENCH_SMOKE=1) shrinks everything to a seconds-scale
canary: it catches a broken save/restore path, not perf regressions.
"""
import os
import shutil
import tempfile
import time

import numpy as np

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _dir_bytes(root: str) -> int:
    total = 0
    for dp, _, fns in os.walk(root):
        for fn in fns:
            total += os.path.getsize(os.path.join(dp, fn))
    return total


def run(rows: list):
    from repro.core import make_index
    from repro.store import IndexStore

    n, dim = (2_000, 32) if SMOKE else (20_000, 64)
    rng = np.random.default_rng(0)
    data = rng.normal(size=(n, dim)).astype(np.float32)
    keys = [f"d{i}" for i in range(n)]

    root = tempfile.mkdtemp(prefix="bench_store_")
    try:
        # ---------------- cold build: the path warm restore replaces ----
        t0 = time.perf_counter()
        idx = make_index("hnsw", metric="cosine", M=8, ef_construction=40,
                         use_bulk_build=True,
                         store=IndexStore(os.path.join(root, "hnsw")))
        idx.bulk_insert(keys, data)
        idx.query(data[0], k=1)               # force device residency
        t_cold = time.perf_counter() - t0
        rows.append((f"store_cold_build_n{n}", t_cold * 1e6,
                     f"ms_per_vec={t_cold / n * 1e3:.3f}"))

        # ---------------- snapshot write --------------------------------
        store = idx._store
        t0 = time.perf_counter()
        store.snapshot(idx)
        t_snap = time.perf_counter() - t0
        nbytes = _dir_bytes(os.path.join(root, "hnsw"))
        rows.append((f"store_snapshot_n{n}", t_snap * 1e6,
                     f"mb={nbytes / 1e6:.1f} "
                     f"mb_per_s={nbytes / 1e6 / max(t_snap, 1e-9):.0f}"))

        # ---------------- warm restore vs cold rebuild ------------------
        t0 = time.perf_counter()
        r = IndexStore(os.path.join(root, "hnsw")).load_index()
        r.query(data[0], k=1)                 # include the device upload
        t_restore = time.perf_counter() - t0
        rows.append((f"store_restore_n{n}", t_restore * 1e6,
                     f"speedup_vs_cold={t_cold / max(t_restore, 1e-9):.1f}x"))

        # ---------------- WAL append overhead (flat: cheapest impl) -----
        m = 200 if SMOKE else 1_000
        extra = rng.normal(size=(m, dim)).astype(np.float32)
        plain = make_index("flat", dim=dim, metric="cosine")
        t0 = time.perf_counter()
        for j in range(m):
            plain.insert(f"p{j}", extra[j])
        t_plain = time.perf_counter() - t0
        logged = make_index("flat", dim=dim, metric="cosine",
                            store=IndexStore(os.path.join(root, "flat")))
        t0 = time.perf_counter()
        for j in range(m):
            logged.insert(f"p{j}", extra[j])
        t_logged = time.perf_counter() - t0
        rows.append((f"store_wal_append_m{m}", t_logged / m * 1e6,
                     f"overhead={(t_logged - t_plain) / m * 1e6:.1f}us_per_op"))

        # ---------------- WAL replay rate -------------------------------
        t0 = time.perf_counter()
        IndexStore(os.path.join(root, "flat")).load_index()
        t_replay = time.perf_counter() - t0
        rows.append((f"store_wal_replay_m{m}", t_replay / m * 1e6,
                     f"ops_per_s={m / max(t_replay, 1e-9):.0f}"))

        # ---------------- secure-delete compaction ----------------------
        for j in range(0, m, 10):             # tombstone 10% of the rows
            logged.delete(f"p{j}")
        t0 = time.perf_counter()
        logged._store.compact(logged)
        t_compact = time.perf_counter() - t0
        rows.append((f"store_compact_m{m}", t_compact * 1e6,
                     f"deleted={m // 10} live={logged.size}"))
    finally:
        shutil.rmtree(root, ignore_errors=True)
