"""Paper §5 query claim ("real time at 1M") + the §3.1 recall/ef tradeoff.

Measures batched search latency + recall@10 vs efSearch through the
unified ``VectorIndex`` protocol (hnsw backend), and the exact flat-index
scan latency (the brute-force bound), at CPU-feasible scale.
"""
import time

import jax
import numpy as np

from repro.core import make_index
from repro.data.synthetic import make_corpus
from repro.kernels import ref
import jax.numpy as jnp


def _key_recall(found_keys, true_i) -> float:
    hits = 0
    for row, t in zip(found_keys, np.asarray(true_i)):
        got = {int(k[1:]) for k in row if k is not None}
        hits += len(got & {int(x) for x in t})
    return hits / true_i.size


def run(rows: list):
    n, dim, q_n = 20_000, 64, 64
    data = make_corpus(n, dim, seed=0)
    rng = np.random.default_rng(1)
    # realistic retrieval: queries near the corpus manifold (perturbed rows)
    queries = (data[rng.integers(0, n, q_n)]
               + 0.15 * rng.normal(size=(q_n, dim)).astype(np.float32))
    keys = [f"d{i}" for i in range(n)]
    idx = make_index("hnsw", metric="cosine", M=8, ef_construction=60)
    idx.bulk_insert(keys, data)
    qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
    datan = data / np.linalg.norm(data, axis=1, keepdims=True)
    _, true_i = ref.distance_topk_ref(jnp.asarray(datan), jnp.asarray(qn), 10)
    true_i = np.asarray(true_i)

    for ef in (16, 32, 64, 128):
        found, _ = idx.query(queries, k=10, ef=ef)        # compile + sync
        t0 = time.perf_counter()
        for _ in range(3):
            found, d = idx.query(queries, k=10, ef=ef)
            jax.block_until_ready(d) if hasattr(d, "block_until_ready") \
                else None
        us = (time.perf_counter() - t0) / 3 / q_n * 1e6
        rec = _key_recall(found, true_i)
        rows.append((f"hnsw_query_n{n}_ef{ef}", us, f"recall@10={rec:.3f}"))

    flat = make_index("flat", metric="cosine", dim=dim)
    flat.bulk_insert(keys, data)
    flat.query(queries, k=10)                             # compile + pack
    t0 = time.perf_counter()
    for _ in range(3):
        found, _ = flat.query(queries, k=10)
    us = (time.perf_counter() - t0) / 3 / q_n * 1e6
    rows.append((f"flat_query_n{n}", us,
                 f"exact recall@10={_key_recall(found, true_i):.3f}"))
