"""Paper §5 query claim ("real time at 1M") + the §3.1 recall/ef tradeoff.

Measures batched HNSW search latency + recall@10 vs efSearch, and the exact
flat-index scan latency (the brute-force bound), at CPU-feasible scale.
"""
import time

import jax
import numpy as np

from repro.core import hnsw, hnsw_build
from repro.core.flat import FlatIndex
from repro.data.synthetic import make_corpus
from repro.kernels import ref
import jax.numpy as jnp


def run(rows: list):
    n, dim, q_n = 20_000, 64, 64
    data = make_corpus(n, dim, seed=0)
    rng = np.random.default_rng(1)
    # realistic retrieval: queries near the corpus manifold (perturbed rows)
    queries = (data[rng.integers(0, n, q_n)]
               + 0.15 * rng.normal(size=(q_n, dim)).astype(np.float32))
    g = hnsw_build.build_sequential(data, M=8, ef_construction=60)
    dg = hnsw.to_device_graph(g)
    qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
    _, true_i = ref.distance_topk_ref(jnp.asarray(g.vectors),
                                      jnp.asarray(qn), 10)

    for ef in (16, 32, 64, 128):
        ids, _ = hnsw.search_graph(dg, queries, k=10, ef=ef)   # compile
        jax.block_until_ready(ids)
        t0 = time.perf_counter()
        for _ in range(3):
            ids, _ = hnsw.search_graph(dg, queries, k=10, ef=ef)
            jax.block_until_ready(ids)
        us = (time.perf_counter() - t0) / 3 / q_n * 1e6
        rec = hnsw.recall_at_k(np.asarray(ids), np.asarray(true_i))
        rows.append((f"hnsw_query_n{n}_ef{ef}", us, f"recall@10={rec:.3f}"))

    flat = FlatIndex.build(data)
    d, i = flat.query(queries, k=10)
    jax.block_until_ready(i)
    t0 = time.perf_counter()
    for _ in range(3):
        d, i = flat.query(queries, k=10)
        jax.block_until_ready(i)
    us = (time.perf_counter() - t0) / 3 / q_n * 1e6
    rows.append((f"flat_query_n{n}", us, "exact"))
