"""Paper §5 query claim ("real time at 1M") + the §3.1 recall/ef tradeoff
+ the batched retrieval serving layer's B-sweep (DESIGN.md §6).

Rows:
  hnsw_query_n{N}_ef{EF}    lock-step batched search latency + recall@10
  hnsw_query_n{N}_ef64_{fused,jnp}
                            layer-0 beam implementation head-to-head
                            (DESIGN.md §12): same graph + queries, fused
                            one-launch kernel vs per-hop jnp reference;
                            derived carries recall@10 and dispatches=
  flat_query_n{N}           exact scan latency (the brute-force bound)
  engine_B{1,8,32,128}      RetrievalEngine per-query latency/QPS at each
                            bucket size (cache off — device throughput)

Measures batched search latency + recall@10 vs efSearch through the
unified ``VectorIndex`` protocol (hnsw backend), and the exact flat-index
scan latency, at CPU-feasible scale. Smoke mode (REPRO_BENCH_SMOKE=1)
shrinks sizes for CI.
"""
import os
import time

import jax
import numpy as np

from repro.core import make_index
from repro.core import dispatch as jdispatch
from repro.core import hnsw as jhnsw
from repro.data.synthetic import make_corpus
from repro.kernels import ref
from repro.serve.retrieval import RetrievalEngine
import jax.numpy as jnp

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _key_recall(found_keys, true_i) -> float:
    hits = 0
    for row, t in zip(found_keys, np.asarray(true_i)):
        got = {int(k[1:]) for k in row if k is not None}
        hits += len(got & {int(x) for x in t})
    return hits / true_i.size


def run(rows: list):
    # q_n stays at its historical value so hnsw_query_*/flat_query_* rows
    # keep measuring the same batch shape PR-over-PR; the engine sweep
    # below draws its own workload sized to cover the largest bucket.
    n, dim, q_n = (2_000, 32, 32) if SMOKE else (20_000, 64, 64)
    eng_n = 32 if SMOKE else 128
    reps = 1 if SMOKE else 3
    data = make_corpus(n, dim, seed=0)
    rng = np.random.default_rng(1)
    # realistic retrieval: queries near the corpus manifold (perturbed
    # rows); drawn exactly as in earlier PRs so recall rows are comparable
    queries = (data[rng.integers(0, n, q_n)]
               + 0.15 * rng.normal(size=(q_n, dim)).astype(np.float32))
    eng_queries = (data[rng.integers(0, n, eng_n)]
                   + 0.15 * rng.normal(size=(eng_n, dim)).astype(np.float32))
    keys = [f"d{i}" for i in range(n)]
    idx = make_index("hnsw", metric="cosine", M=8, ef_construction=60)
    idx.bulk_insert(keys, data)
    qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
    datan = data / np.linalg.norm(data, axis=1, keepdims=True)
    _, true_i = ref.distance_topk_ref(jnp.asarray(datan), jnp.asarray(qn), 10)
    true_i = np.asarray(true_i)

    for ef in (16, 64) if SMOKE else (16, 32, 64, 128):
        found, _ = idx.query(queries, k=10, ef=ef)        # compile + sync
        t0 = time.perf_counter()
        for _ in range(reps):
            found, d = idx.query(queries, k=10, ef=ef)
            jax.block_until_ready(d) if hasattr(d, "block_until_ready") \
                else None
        us = (time.perf_counter() - t0) / reps / q_n * 1e6
        rec = _key_recall(found, true_i)
        rows.append((f"hnsw_query_n{n}_ef{ef}", us, f"recall@10={rec:.3f}"))

    # ---- fused vs jnp layer-0 beam (DESIGN.md §12): same graph, same
    # queries, both implementations head-to-head at ef=64. The smoke CI
    # job asserts the fused row's us_per_call <= the jnp row's (0.9x
    # noise tolerance) and that it reports dispatches=1 — the launch
    # economics the kernel exists for. The corpus is larger than the
    # ef-sweep's so the per-hop dispatch overhead the fusion removes is
    # actually visible in the jnp row.
    bn = 2_000 if SMOKE else 100_000
    bdata = make_corpus(bn, dim, seed=2)
    bidx = make_index("hnsw", metric="cosine", M=8, ef_construction=60,
                      use_bulk_build=True)
    bidx.bulk_insert([f"b{i}" for i in range(bn)], bdata)
    bq = (bdata[rng.integers(0, bn, q_n)]
          + 0.15 * rng.normal(size=(q_n, dim)).astype(np.float32))
    bqn = bq / np.linalg.norm(bq, axis=1, keepdims=True)
    bdn = bdata / np.linalg.norm(bdata, axis=1, keepdims=True)
    _, btrue = ref.distance_topk_ref(jnp.asarray(bdn), jnp.asarray(bqn), 10)
    btrue = np.asarray(btrue)
    dg = bidx._dg()
    for impl in ("fused", "jnp"):
        ids, d = jhnsw.search_graph(dg, bq, k=10, ef=64,
                                    beam_impl=impl)  # compile + sync
        jax.block_until_ready(d)
        jdispatch.reset("hnsw.beam_launches")
        _, d = jhnsw.search_graph(dg, bq, k=10, ef=64, beam_impl=impl)
        jax.block_until_ready(d)
        disp = jdispatch.get("hnsw.beam_launches")
        t0 = time.perf_counter()
        for _ in range(reps):
            ids, d = jhnsw.search_graph(dg, bq, k=10, ef=64, beam_impl=impl)
            jax.block_until_ready(d)
        us = (time.perf_counter() - t0) / reps / q_n * 1e6
        rec = jhnsw.recall_at_k(np.asarray(ids), btrue)
        rows.append((f"hnsw_query_n{bn}_ef64_{impl}", us,
                     f"recall@10={rec:.3f} dispatches={disp}"))

    flat = make_index("flat", metric="cosine", dim=dim)
    flat.bulk_insert(keys, data)
    flat.query(queries, k=10)                             # compile + pack
    t0 = time.perf_counter()
    for _ in range(reps):
        found, _ = flat.query(queries, k=10)
    us = (time.perf_counter() - t0) / reps / q_n * 1e6
    rows.append((f"flat_query_n{n}", us,
                 f"exact recall@10={_key_recall(found, true_i):.3f}"))

    # ---- RetrievalEngine bucket sweep: per-query cost vs batch size.
    # Cache off so this is pure coalesced device throughput; the cached
    # path is measured in bench_serve (retrieval_B32_cached). eng_n covers
    # the largest bucket so every row measures its labelled batch shape.
    for B in (1, 8, 32) if SMOKE else (1, 8, 32, 128):
        eng = RetrievalEngine(idx, max_batch=B, cache_size=0)
        eng.retrieve(eng_queries[:B], k=10)               # warm this bucket
        t0 = time.perf_counter()
        for _ in range(reps):
            for lo in range(0, eng_n, B):
                eng.retrieve(eng_queries[lo:lo + B], k=10)
        us = (time.perf_counter() - t0) / reps / eng_n * 1e6
        rows.append((f"engine_B{B}", us, f"qps={1e6 / us:.0f}"))
