"""Paper §5 construction claim: 1M x 384-d inserts (M=5, efC=20) took
~94 min in Chrome => 5.64 ms/vector. We measure our builders at CPU-feasible
scale and report ms/vector + the speedup over the browser baseline.

Also: the incremental device-graph sync micro-benchmark (DESIGN.md §3) —
after a query makes the graph device-resident, an insert must upload only
its dirty rows, not re-convert all N rows."""
import time

import jax
import numpy as np

from repro.core import hnsw_build
from repro.data.synthetic import make_corpus

PAPER_MS_PER_VEC = 94 * 60 * 1000 / 1_000_000      # 5.64 ms


def _synthetic_hnsw_index(n: int, dim: int, M: int, seed: int = 0):
    """An HNSW VectorIndex over a fabricated random M-regular graph: the
    sync benchmark measures host->device transfer, not graph quality, and
    building a real 100k graph on CPU would dominate the suite's runtime."""
    from repro.core.interface import HNSW

    rng = np.random.default_rng(seed)
    v = rng.normal(size=(n, dim)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    b = hnsw_build.SequentialBuilder(dim, M=M, ef_construction=20,
                                     metric="cosine",
                                     capacity=n + 256)   # headroom: inserts
    b.vectors[:n] = v                                    # must not regrow
    b.neighbors0[:n] = rng.integers(0, n, size=(n, 2 * M)).astype(np.int32)
    b.n, b.entry, b.max_level = n, 0, 0
    idx = HNSW(distance_function="cosine", M=M, ef_construction=20)
    idx._builder = b
    idx._keys = [f"d{i}" for i in range(n)]
    idx._key2id = {k: i for i, k in enumerate(idx._keys)}
    return idx


def run(rows: list):
    for n, dim in [(2000, 384), (5000, 64)]:
        data = make_corpus(n, dim, seed=0)
        t0 = time.perf_counter()
        hnsw_build.build_sequential(data, M=5, ef_construction=20)
        dt = time.perf_counter() - t0
        ms = dt / n * 1e3
        rows.append((f"build_seq_n{n}_d{dim}", ms * 1e3,
                     f"{PAPER_MS_PER_VEC / ms:.1f}x_vs_paper"))
        t0 = time.perf_counter()
        hnsw_build.bulk_build(data, M=5, ef_construction=20,
                              bootstrap=256, batch_size=1024)
        dt = time.perf_counter() - t0
        ms = dt / n * 1e3
        rows.append((f"build_bulk_n{n}_d{dim}", ms * 1e3,
                     f"{PAPER_MS_PER_VEC / ms:.1f}x_vs_paper"))

    # ---------------- incremental sync vs full re-upload (N=100k) ----------
    n, dim, M = 100_000, 64, 8
    idx = _synthetic_hnsw_index(n, dim, M)
    rng = np.random.default_rng(1)
    idx.query(rng.normal(size=dim).astype(np.float32), k=1, ef=20)  # resident
    # warm both sync paths (compile the donated scatter, page the buffers)
    idx.insert("warm-0", rng.normal(size=dim).astype(np.float32))
    jax.block_until_ready(idx._dg())
    idx._device_graph = None
    jax.block_until_ready(idx._dg())
    reps = 5
    t_inc = t_full = 0.0
    dirty = 0
    for r in range(reps):
        # insert-after-query, incremental path: only dirty rows travel
        idx.insert(f"new-inc-{r}", rng.normal(size=dim).astype(np.float32))
        dirty += len(idx._builder.journal)
        t0 = time.perf_counter()
        dg = idx._dg()
        jax.block_until_ready(dg)
        t_inc += time.perf_counter() - t0
        # same insert, forced full to_device_graph re-upload
        idx.insert(f"new-full-{r}", rng.normal(size=dim).astype(np.float32))
        idx._device_graph = None
        t0 = time.perf_counter()
        dg = idx._dg()
        jax.block_until_ready(dg)
        t_full += time.perf_counter() - t0
    us_inc = t_inc / reps * 1e6
    us_full = t_full / reps * 1e6
    rows.append((f"sync_incremental_n{n}", us_inc,
                 f"dirty_rows={dirty // reps}"))
    rows.append((f"sync_full_rebuild_n{n}", us_full,
                 f"{us_full / max(us_inc, 1e-9):.1f}x_slower_than_incremental"))
