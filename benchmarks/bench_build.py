"""Paper §5 construction claim: 1M x 384-d inserts (M=5, efC=20) took
~94 min in Chrome => 5.64 ms/vector. We measure our builders at CPU-feasible
scale and report ms/vector + the speedup over the browser baseline.

Build rows (DESIGN.md §13): `build_seq_*` is the faithful sequential
reference; `build_bulk_*` is the device-resident bulk ingest — ONE
capacity upload, per-batch adjacency-only scatter, batched select/connect
ops — timed warm (a first pass pays the one-time jit of the batched ops;
the measured pass reuses it, which is the steady-state an ingest service
sees); `build_bulk_legacy_*` is the pre-§13 bulk path that re-uploaded
the full graph every batch, timed after the resident row so the shared
beam-search compile is warm for it too. The derived columns carry the
honesty metrics CI asserts on: `h2d_bytes` (host->device traffic from
the `hnsw.h2d_bytes` counter), `h2d_vs_legacy` (resident / legacy —
dirty-rows-only should sit well under 1), `beam_launches`
(`hnsw.beam_launches` delta: one fused launch per batch), `vec_per_s`,
and `recall10` vs the exact oracle on a held-out query set.

Also: the incremental device-graph sync micro-benchmark (DESIGN.md §3) —
after a query makes the graph device-resident, an insert must upload only
its dirty rows, not re-convert all N rows."""
import os
import time

import jax
import numpy as np

from repro.core import dispatch, hnsw_build
from repro.core import hnsw as jhnsw
from repro.data.synthetic import make_corpus

PAPER_MS_PER_VEC = 94 * 60 * 1000 / 1_000_000      # 5.64 ms

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _synthetic_hnsw_index(n: int, dim: int, M: int, seed: int = 0):
    """An HNSW VectorIndex over a fabricated random M-regular graph: the
    sync benchmark measures host->device transfer, not graph quality, and
    building a real 100k graph on CPU would dominate the suite's runtime."""
    from repro.core.interface import HNSW

    rng = np.random.default_rng(seed)
    v = rng.normal(size=(n, dim)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    b = hnsw_build.SequentialBuilder(dim, M=M, ef_construction=20,
                                     metric="cosine",
                                     capacity=n + 256)   # headroom: inserts
    b.vectors[:n] = v                                    # must not regrow
    b.neighbors0[:n] = rng.integers(0, n, size=(n, 2 * M)).astype(np.int32)
    b.n, b.entry, b.max_level = n, 0, 0
    idx = HNSW(distance_function="cosine", M=M, ef_construction=20)
    idx._builder = b
    idx._keys = [f"d{i}" for i in range(n)]
    idx._key2id = {k: i for i, k in enumerate(idx._keys)}
    return idx


def _recall10(g, q: np.ndarray, true10: np.ndarray) -> float:
    ids, _ = jhnsw.search_graph(jhnsw.to_device_graph(g), q, k=10, ef=64)
    return jhnsw.recall_at_k(np.asarray(ids), true10)


def _true10(data: np.ndarray, q: np.ndarray) -> np.ndarray:
    vn = hnsw_build.normalize_rows(data)
    qn = hnsw_build.normalize_rows(q)
    return np.argsort(1.0 - qn @ vn.T, axis=1, kind="stable")[:, :10]


def _bulk_row(rows: list, name: str, data: np.ndarray, q, true10,
              *, fn, bootstrap: int, batch_size: int, warm: bool,
              extra: str = "") -> float:
    """Time one bulk builder over ``data``; returns wall seconds and
    appends the row. ``warm``: run once un-timed first so the measured
    pass sees compiled batched ops (steady-state ingest)."""
    n = len(data)
    kw = dict(M=5, ef_construction=20, bootstrap=bootstrap,
              batch_size=batch_size)
    if warm:
        fn(data, **kw)
    dispatch.reset("hnsw.h2d_bytes", "hnsw.beam_launches")
    t0 = time.perf_counter()
    g = fn(data, **kw)
    dt = time.perf_counter() - t0
    h2d = dispatch.get("hnsw.h2d_bytes")
    launches = dispatch.get("hnsw.beam_launches")
    rec = "" if q is None else f" recall10={_recall10(g, q, true10):.3f}"
    ms = dt / n * 1e3
    rows.append((name, ms * 1e3,
                 f"vec_per_s={n / dt:.0f} h2d_bytes={h2d}"
                 f" beam_launches={launches}"
                 f" {PAPER_MS_PER_VEC / ms:.1f}x_vs_paper{rec}{extra}"))
    return dt, h2d


def run(rows: list):
    dim = 64
    rng = np.random.default_rng(7)
    sizes = [4000] if SMOKE else [20000, 100000]
    bootstrap, batch_size = (32, 512) if SMOKE else (256, 1024)
    for n in sizes:
        data = make_corpus(n, dim, seed=0)
        q = rng.normal(size=(200, dim)).astype(np.float32)
        true10 = _true10(data, q)
        # sequential reference: full run only at 20k — the paper's 94-min
        # figure extrapolates from exactly this ms/vector
        seq_dt = None
        if n <= 20000:
            t0 = time.perf_counter()
            g = hnsw_build.build_sequential(data, M=5, ef_construction=20)
            seq_dt = time.perf_counter() - t0
            ms = seq_dt / n * 1e3
            rows.append((f"build_seq_n{n}_d{dim}", ms * 1e3,
                         f"vec_per_s={n / seq_dt:.0f}"
                         f" {PAPER_MS_PER_VEC / ms:.1f}x_vs_paper"
                         f" recall10={_recall10(g, q, true10):.3f}"))
        blk_dt, blk_h2d = _bulk_row(
            rows, f"build_bulk_n{n}_d{dim}", data, q, true10,
            fn=hnsw_build.bulk_build, bootstrap=bootstrap,
            batch_size=batch_size, warm=True)
        leg_dt, leg_h2d = _bulk_row(
            rows, f"build_bulk_legacy_n{n}_d{dim}", data, q, true10,
            fn=hnsw_build.bulk_build_legacy, bootstrap=bootstrap,
            batch_size=batch_size, warm=False)
        # honesty column on the resident row: amend with the legacy ratio
        name, us, derived = rows[-2]
        extra = f" h2d_vs_legacy={blk_h2d / max(leg_h2d, 1):.3f}"
        if seq_dt is not None:
            extra += f" speedup_vs_seq={seq_dt / blk_dt:.1f}x"
        rows[-2] = (name, us, derived + extra)

    # ---------------- incremental sync vs full re-upload -------------------
    n, M = (20_000 if SMOKE else 100_000), 8
    idx = _synthetic_hnsw_index(n, dim, M)
    rng = np.random.default_rng(1)
    idx.query(rng.normal(size=dim).astype(np.float32), k=1, ef=20)  # resident
    # warm both sync paths (compile the donated scatter, page the buffers)
    idx.insert("warm-0", rng.normal(size=dim).astype(np.float32))
    jax.block_until_ready(idx._dg())
    idx._device_graph = None
    jax.block_until_ready(idx._dg())
    reps = 5
    t_inc = t_full = 0.0
    dirty = 0
    for r in range(reps):
        # insert-after-query, incremental path: only dirty rows travel
        idx.insert(f"new-inc-{r}", rng.normal(size=dim).astype(np.float32))
        dirty += len(idx._builder.journal)
        t0 = time.perf_counter()
        dg = idx._dg()
        jax.block_until_ready(dg)
        t_inc += time.perf_counter() - t0
        # same insert, forced full to_device_graph re-upload
        idx.insert(f"new-full-{r}", rng.normal(size=dim).astype(np.float32))
        idx._device_graph = None
        t0 = time.perf_counter()
        dg = idx._dg()
        jax.block_until_ready(dg)
        t_full += time.perf_counter() - t0
    us_inc = t_inc / reps * 1e6
    us_full = t_full / reps * 1e6
    rows.append((f"sync_incremental_n{n}", us_inc,
                 f"dirty_rows={dirty // reps}"))
    rows.append((f"sync_full_rebuild_n{n}", us_full,
                 f"{us_full / max(us_inc, 1e-9):.1f}x_slower_than_incremental"))
