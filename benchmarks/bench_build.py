"""Paper §5 construction claim: 1M x 384-d inserts (M=5, efC=20) took
~94 min in Chrome => 5.64 ms/vector. We measure our builders at CPU-feasible
scale and report ms/vector + the speedup over the browser baseline."""
import time

import numpy as np

from repro.core import hnsw_build
from repro.data.synthetic import make_corpus

PAPER_MS_PER_VEC = 94 * 60 * 1000 / 1_000_000      # 5.64 ms


def run(rows: list):
    for n, dim in [(2000, 384), (5000, 64)]:
        data = make_corpus(n, dim, seed=0)
        t0 = time.perf_counter()
        hnsw_build.build_sequential(data, M=5, ef_construction=20)
        dt = time.perf_counter() - t0
        ms = dt / n * 1e3
        rows.append((f"build_seq_n{n}_d{dim}", ms * 1e3,
                     f"{PAPER_MS_PER_VEC / ms:.1f}x_vs_paper"))
        t0 = time.perf_counter()
        hnsw_build.bulk_build(data, M=5, ef_construction=20,
                              bootstrap=256, batch_size=1024)
        dt = time.perf_counter() - t0
        ms = dt / n * 1e3
        rows.append((f"build_bulk_n{n}_d{dim}", ms * 1e3,
                     f"{PAPER_MS_PER_VEC / ms:.1f}x_vs_paper"))
